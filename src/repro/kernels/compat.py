"""Version-adaptive JAX/Pallas compatibility surface.

The platform target is a moving API: the Pallas TPU compiler-params class
was renamed (``TPUCompilerParams`` on jax 0.4.x -> ``CompilerParams`` on
0.5+), the path-aware pytree helpers migrated from ``jax.tree_util`` onto
``jax.tree``, and the set of accepted compiler-param fields drifts between
releases. Mirroring the paper's capability discipline (§4: a capability is
what compiles and runs, not what a table attests), this module probes the
*installed* JAX once at import time and exposes one stable surface:

    compiler_params(dimension_semantics=..., ...)  -> params pallas_call takes
    pallas_call_params(...)                        -> kwargs dict (or {} when
                                                      no params class exists)
    tree_flatten_with_path / tree_map_with_path    -> path-aware pytree ops
    interpret_mode()                               -> True off-TPU

Every kernel family routes through this layer; nothing else in the tree may
name the versioned classes directly (enforced by the conformance suite).
"""

from __future__ import annotations

import dataclasses
import functools
import re
from typing import Any, Callable

import jax

try:  # pallas is present in every supported jax, but stay import-safe
    from jax.experimental.pallas import tpu as _pltpu
except Exception:  # pragma: no cover - exotic builds without pallas
    _pltpu = None


# ---------------------------------------------------------------------------
# Version probing
# ---------------------------------------------------------------------------


@functools.cache
def jax_version() -> tuple[int, int, int]:
    """The installed jax version as a comparable (major, minor, patch)."""
    parts = re.findall(r"\d+", jax.__version__)[:3]
    parts += ["0"] * (3 - len(parts))
    return tuple(int(p) for p in parts)  # type: ignore[return-value]


@functools.cache
def _compiler_params_cls() -> type | None:
    """The Pallas TPU compiler-params class under whichever name this jax
    ships it. Resolution is structural (probe both names), never a version
    pin — a backport or rename lands here automatically."""
    if _pltpu is None:
        return None
    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(_pltpu, name, None)
        if cls is not None:
            return cls
    return None


@functools.cache
def compiler_param_fields() -> frozenset[str]:
    """Field names the installed compiler-params class accepts."""
    cls = _compiler_params_cls()
    if cls is None:
        return frozenset()
    if dataclasses.is_dataclass(cls):
        return frozenset(f.name for f in dataclasses.fields(cls))
    import inspect

    try:
        return frozenset(inspect.signature(cls).parameters)
    except (TypeError, ValueError):  # pragma: no cover
        return frozenset()


# ---------------------------------------------------------------------------
# compiler_params surface
# ---------------------------------------------------------------------------


def compiler_params(**kwargs: Any):
    """Build the TPU compiler-params object for this jax, dropping any field
    the installed class does not know (a field that vanished in a rename is a
    hint we can live without, not an error)."""
    cls = _compiler_params_cls()
    if cls is None:
        return None
    accepted = compiler_param_fields()
    kept = {k: v for k, v in kwargs.items() if k in accepted and v is not None}
    return cls(**kept)


def pallas_call_params(**kwargs: Any) -> dict[str, Any]:
    """``compiler_params=...`` kwargs for ``pl.pallas_call``, or ``{}`` when
    the installed Pallas exposes no params class (interpret-only builds)."""
    params = compiler_params(**kwargs)
    if params is None:
        return {}
    return {"compiler_params": params}


# ---------------------------------------------------------------------------
# Path-aware pytree helpers (jax.tree.* on 0.5+, jax.tree_util on 0.4.x)
# ---------------------------------------------------------------------------


def _tree_fn(modern_name: str, legacy_name: str) -> Callable:
    tree_mod = getattr(jax, "tree", None)
    fn = getattr(tree_mod, modern_name, None) if tree_mod is not None else None
    if fn is None:
        fn = getattr(jax.tree_util, legacy_name)
    return fn


def tree_flatten_with_path(tree: Any, is_leaf: Callable | None = None):
    """(path, leaf) pairs + treedef, under whichever module ships it."""
    return _tree_fn("flatten_with_path", "tree_flatten_with_path")(
        tree, is_leaf=is_leaf)


def tree_map_with_path(f: Callable, tree: Any, *rest: Any,
                       is_leaf: Callable | None = None):
    return _tree_fn("map_with_path", "tree_map_with_path")(
        f, tree, *rest, is_leaf=is_leaf)


def tree_path_str(path: Any) -> str:
    """A stable ``a/b/0/c`` rendering of a key path across jax versions."""
    parts = []
    for entry in path:
        for attr in ("key", "idx", "name"):
            if hasattr(entry, attr):
                parts.append(str(getattr(entry, attr)))
                break
        else:
            parts.append(str(entry))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# Scalar-prefetch grid specs
# ---------------------------------------------------------------------------


def prefetch_grid_spec(*, num_scalar_prefetch: int, grid, in_specs,
                       out_specs, scratch_shapes=()):
    """``pltpu.PrefetchScalarGridSpec`` under whichever Pallas ships it, or
    ``None`` when the installed build has no scalar prefetch (callers fall
    back to a gather-outside-the-kernel path). Scalar-prefetch arguments are
    how a kernel's BlockSpec index maps read a page table before the body
    runs — the paged-KV decode path resolves its arena blocks through this."""
    if _pltpu is None:
        return None
    cls = getattr(_pltpu, "PrefetchScalarGridSpec", None)
    if cls is None:
        return None
    return cls(num_scalar_prefetch=num_scalar_prefetch, grid=grid,
               in_specs=in_specs, out_specs=out_specs,
               scratch_shapes=scratch_shapes)


# ---------------------------------------------------------------------------
# Named-axis helpers
# ---------------------------------------------------------------------------


def axis_size(name: str):
    """Size of a named mapped axis inside shard_map/pmap. ``jax.lax.axis_size``
    only exists on newer jax; the ``psum(1, axis)`` idiom is the portable
    spelling (it folds to a static int for a constant operand)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(name)
    return jax.lax.psum(1, name)


# ---------------------------------------------------------------------------
# Interpret mode
# ---------------------------------------------------------------------------


@functools.cache
def interpret_mode() -> bool:
    """Pallas ``interpret=True`` everywhere except a real TPU backend — the
    kernel body runs in Python and the oracle sweeps validate it bit-for-bit
    against ref.py on any host."""
    try:
        return jax.default_backend() != "tpu"
    except Exception:  # pragma: no cover - backend probing failed: stay safe
        return True
