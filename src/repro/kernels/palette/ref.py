"""Pure-jnp oracle for palette_matmul: dequantize dense, then matmul.

This is literally the paper's FOLD path (§7.3): the weight expands to dense
fp16 before the data-movement step — same arithmetic as the streaming
kernel, but the bytes that cross memory are full-width. The benchmark
contrasts the two paths' byte counts; the tests contrast their values
(which must match exactly up to accumulation order).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.palette.palette_matmul import unpack_dense


def palette_matmul_ref(a, packed, lut):
    w = unpack_dense(packed, lut.astype(jnp.float32))
    return jax.lax.dot_general(a, w.astype(a.dtype), (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32
                               ).astype(a.dtype)
