"""palette_matmul: int4 palette-LUT weights, dequantized inside the kernel.

The paper's headline compression result (§7.3): the int4 lookup-table form
*streams* on every ANE generation — four-bit indices cross DRAM and the
16-entry fp16 codebook reconstructs them at the multiplier input, 2.37x
faster than fp16 on a bandwidth-bound stack. The TPU-native transcription:
the packed nibbles cross HBM->VMEM (4x fewer weight bytes), and the
codebook lookup happens *in the kernel*, between the VMEM load and the MXU
dot — the multiplier-input reconstruction point, exactly.

TPU Pallas has no general VMEM gather, so the 16-entry lookup is a 4-level
select tree over the index bits (`select_from_table`) — each level one
vectorized where, fully VPU-resident.

Weight layout: pairs packed along K (low nibble = even row), so a (bk, bn)
dense block unpacks from a (bk/2, bn) packed block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat
from repro.kernels.common import (cdiv, interpret_mode, pad_to, pick_block,
                                  select_from_table)


def pack_kn(w: np.ndarray, iters: int = 12) -> tuple[np.ndarray, np.ndarray]:
    """Fit a 16-entry codebook (Lloyd) and pack indices along K, low nibble
    first. Returns (packed (K/2, N) uint8, lut (16,) float32)."""
    w = np.asarray(w, dtype=np.float32)
    assert w.ndim == 2 and w.shape[0] % 2 == 0
    flat = w.reshape(-1)
    code = np.quantile(flat, np.linspace(0, 1, 16)).astype(np.float32)
    for _ in range(iters):
        idx = np.argmin(np.abs(flat[:, None] - code[None, :]), axis=1)
        for c in range(16):
            sel = flat[idx == c]
            if sel.size:
                code[c] = sel.mean()
    code = np.sort(code)
    idx = np.argmin(np.abs(w[:, :, None] - code[None, None, :]),
                    axis=-1).astype(np.uint8)
    lo, hi = idx[0::2], idx[1::2]
    return (lo | (hi << 4)).astype(np.uint8), code


def unpack_dense(packed: jnp.ndarray, lut: jnp.ndarray) -> jnp.ndarray:
    """Reference dequantization (the FOLD path: dense fp16 materialized)."""
    lo = (packed & 0xF).astype(jnp.int32)
    hi = (packed >> 4).astype(jnp.int32)
    k2, n = packed.shape
    idx = jnp.stack([lo, hi], axis=1).reshape(k2 * 2, n)
    return lut[idx]


def _kernel(a_ref, w_ref, lut_ref, o_ref, acc_ref, *, nk, out_dtype):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    packed = w_ref[...]                          # (bk/2, bn) uint8 in VMEM
    lo = (packed & 0xF).astype(jnp.int32)
    hi = (packed >> 4).astype(jnp.int32)
    entries = [lut_ref[0, i] for i in range(16)]
    w_lo = select_from_table(lo, entries)        # dequant at the MXU input
    w_hi = select_from_table(hi, entries)
    bk2, bn = packed.shape
    w = jnp.stack([w_lo, w_hi], axis=1).reshape(bk2 * 2, bn)
    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], w.astype(a_ref.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k_idx == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def palette_matmul(
    a: jnp.ndarray,                 # (M, K)
    packed: jnp.ndarray,            # (K/2, N) uint8
    lut: jnp.ndarray,               # (16,)
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
) -> jnp.ndarray:
    m, k = a.shape
    k2, n = packed.shape
    assert k == 2 * k2, (a.shape, packed.shape)
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    bk = max(16, pick_block(k, bk))
    ap = pad_to(pad_to(a, 0, bm), 1, bk)
    wp = pad_to(pad_to(packed, 0, bk // 2), 1, bn)
    nm, nn, nk = cdiv(ap.shape[0], bm), cdiv(wp.shape[1], bn), cdiv(ap.shape[1], bk)
    lut2 = lut.astype(jnp.float32).reshape(1, 16)

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, out_dtype=a.dtype),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 16), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nm * bm, nn * bn), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret_mode(),
        **compat.pallas_call_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(ap, wp, lut2)
    return out[:m, :n]
