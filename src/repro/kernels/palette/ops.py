"""Public wrapper: pack once, stream many (compile-once / dispatch-many).

`PaletteLinear` holds the packed weight + codebook and exposes the matmul;
`hbm_bytes()` reports what actually crosses memory per dispatch — the number
the compression benchmarks check against the paper's 2.37x stream gain.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.kernels.palette.palette_matmul import pack_kn, palette_matmul


@dataclasses.dataclass
class PaletteLinear:
    packed: jnp.ndarray
    lut: jnp.ndarray
    shape: tuple[int, int]

    @classmethod
    def pack(cls, w: np.ndarray) -> "PaletteLinear":
        packed, lut = pack_kn(w)
        return cls(jnp.asarray(packed), jnp.asarray(lut), tuple(w.shape))

    def __call__(self, a: jnp.ndarray) -> jnp.ndarray:
        return palette_matmul(a, self.packed, self.lut)

    def hbm_bytes(self) -> int:
        return self.packed.size * 1 + self.lut.size * 4

    def dense_bytes(self) -> int:
        return self.shape[0] * self.shape[1] * 2
