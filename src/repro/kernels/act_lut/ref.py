"""Oracle for act_lut: `core.numerics.LutTable.__call__` (numpy, fp16-exact)."""

from __future__ import annotations

import numpy as np

from repro.core.numerics import LutTable, build_lut


def act_lut_ref(x: np.ndarray, table: LutTable) -> np.ndarray:
    return table(np.asarray(x, dtype=np.float64))


def table_arrays(table: LutTable):
    """(xs, slopes, intercepts, clamps) arrays the kernel consumes."""
    return (np.asarray(table.xs, np.float32),
            np.asarray(table.slopes, np.float32),
            np.asarray(table.intercepts, np.float32),
            np.asarray([table.lo_clamp, table.hi_clamp], np.float32))


__all__ = ["act_lut_ref", "build_lut", "table_arrays"]
