"""act_lut: 33-knot piecewise-linear activation evaluation (paper §3.5).

The engine evaluates every nonlinear activation through a 33-knot PWL table:
the input maps onto one of 32 segments, the bracketing segment evaluates as
slope*x + intercept, and values past the domain clamp to the end-knot
asymptote. A NaN coerces to the hi clamp (the +inf input coercion of §3.6).

The kernel is gather-free, as the VPU wants it:
  * segment index = sum of 32 vectorized (x >= knot_i) compares;
  * slope/intercept fetch = 5-level select tree over the 32 segment values.

Tables come from `core.numerics.build_lut`, the same fit the oracle uses, so
kernel-vs-oracle agreement is exact up to fp rounding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv, interpret_mode, pad_to, select_from_table


def lut_eval(x, xs_ref, sl_ref, ic_ref, cl_ref, *, ane_mode: bool):
    """The in-kernel PWL evaluation, shared verbatim by this kernel and the
    fused `epilogue=` paths of anemm/conv — one body, so "fused" and
    "kernel-then-LUT" are bit-identical by construction. `x` is an fp32
    tile; the table refs are the (1, 33)/(1, 32)/(1, 32)/(1, 2) operands.
    Returns the fp32 result tile (callers round at their own store)."""
    if ane_mode:
        x = jnp.where(jnp.isnan(x), jnp.inf, x)       # NaN -> +inf coercion
    # segment index: 32 vectorized compares (knots 1..32), no gather
    idx = jnp.zeros(x.shape, jnp.int32)
    for i in range(1, 33):
        idx += (x >= xs_ref[0, i]).astype(jnp.int32)
    idx = jnp.clip(idx, 0, 31)
    slope = select_from_table(idx, [sl_ref[0, i] for i in range(32)])
    icept = select_from_table(idx, [ic_ref[0, i] for i in range(32)])
    y = slope * x + icept
    lo_clamp, hi_clamp = cl_ref[0, 0], cl_ref[0, 1]
    y = jnp.where(x < xs_ref[0, 0], lo_clamp, y)
    y = jnp.where(x > xs_ref[0, 32], hi_clamp, y)
    if ane_mode:
        y = y.astype(jnp.float16).astype(jnp.float32)  # fp16 output port
    return y


def _kernel(x_ref, xs_ref, sl_ref, ic_ref, cl_ref, o_ref, *, ane_mode: bool):
    y = lut_eval(x_ref[...].astype(jnp.float32), xs_ref, sl_ref, ic_ref,
                 cl_ref, ane_mode=ane_mode)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ane_mode", "block"))
def act_lut(
    x: jnp.ndarray,
    xs: jnp.ndarray,        # (33,) knot abscissae
    slopes: jnp.ndarray,    # (32,)
    icepts: jnp.ndarray,    # (32,)
    clamps: jnp.ndarray,    # (2,) lo/hi asymptotes
    *,
    ane_mode: bool = True,
    block: int = 1024,
) -> jnp.ndarray:
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    cols = min(block, max(n, 1))
    flat = pad_to(flat, 0, cols)
    rows = flat.shape[0] // cols
    x2 = flat.reshape(rows, cols)
    brows = min(8, rows)
    x2 = pad_to(x2, 0, brows)
    nr = cdiv(x2.shape[0], brows)

    out = pl.pallas_call(
        functools.partial(_kernel, ane_mode=ane_mode),
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((brows, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, 33), lambda i: (0, 0)),
            pl.BlockSpec((1, 32), lambda i: (0, 0)),
            pl.BlockSpec((1, 32), lambda i: (0, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((brows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret_mode(),
    )(x2, xs.reshape(1, 33).astype(jnp.float32),
      slopes.reshape(1, 32).astype(jnp.float32),
      icepts.reshape(1, 32).astype(jnp.float32),
      clamps.reshape(1, 2).astype(jnp.float32))
    return out.reshape(-1)[:n].reshape(shape)
