"""Public wrapper: named-activation evaluation through the kernel tables.

`lut_activation("gelu")(x)` evaluates gelu the way the engine does — through
its decoded 33-knot table, including the origin bias and clamp semantics.
Gradients: the PWL derivative is the segment slope; custom_jvp makes the
tables trainable-through (useful for QAT-style experiments)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.numerics import build_lut
from repro.kernels.act_lut.act_lut import act_lut


@functools.cache
def _tables(name: str):
    t = build_lut(name)
    return (jnp.asarray(np.asarray(t.xs, np.float32)),
            jnp.asarray(np.asarray(t.slopes, np.float32)),
            jnp.asarray(np.asarray(t.intercepts, np.float32)),
            jnp.asarray(np.asarray([t.lo_clamp, t.hi_clamp], np.float32)))


def lut_activation(name: str, *, ane_mode: bool = True):
    xs, sl, ic, cl = _tables(name)

    @jax.custom_jvp
    def f(x):
        return act_lut(x, xs, sl, ic, cl, ane_mode=ane_mode)

    @f.defjvp
    def _jvp(primals, tangents):
        (x,), (dx,) = primals, tangents
        y = f(x)
        # derivative = segment slope (0 outside the domain)
        idx = jnp.clip(jnp.searchsorted(xs, x.astype(jnp.float32)) - 1, 0, 31)
        g = sl[idx]
        g = jnp.where((x < xs[0]) | (x > xs[-1]), 0.0, g)
        return y, (g * dx.astype(jnp.float32)).astype(y.dtype)

    return f
