"""Public wrapper: named-activation evaluation through the kernel tables.

`lut_activation("gelu")(x)` evaluates gelu the way the engine does — through
its decoded 33-knot table, including the origin bias and clamp semantics.
Gradients: the PWL derivative is the segment slope; custom_jvp makes the
tables trainable-through (useful for QAT-style experiments)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.numerics import build_lut
from repro.kernels.act_lut.act_lut import act_lut


@functools.cache
def _tables_np(name: str):
    t = build_lut(name)
    return (np.asarray(t.xs, np.float32),
            np.asarray(t.slopes, np.float32),
            np.asarray(t.intercepts, np.float32),
            np.asarray([t.lo_clamp, t.hi_clamp], np.float32))


def _tables(name: str):
    # numpy is cached; the jnp conversion happens per call so a table first
    # touched inside a jit trace never leaks a tracer into the cache
    return tuple(jnp.asarray(a) for a in _tables_np(name))


def lut_table_operands(name: str):
    """The (1, 33)/(1, 32)/(1, 32)/(1, 2) fp32 operand arrays a kernel that
    fuses this activation as an epilogue passes alongside its own inputs
    (constant BlockSpecs; see anemm/conv)."""
    xs, sl, ic, cl = _tables(name)
    return (xs.reshape(1, 33), sl.reshape(1, 32), ic.reshape(1, 32),
            cl.reshape(1, 2))


def lut_apply_ref(x: jnp.ndarray, name: str, *, ane_mode: bool = True):
    """Pure-jnp PWL evaluation — the oracle side of the fused epilogues and
    the undispatched model path. Same arithmetic as `act_lut.lut_eval` (the
    segment fetch is a gather here, a select tree there; the selected values
    and the fp32 slope*x+icept are identical), so it agrees with the kernel
    exactly."""
    xs, sl, ic, cl = _tables(name)
    xf = x.astype(jnp.float32)
    if ane_mode:
        xf = jnp.where(jnp.isnan(xf), jnp.inf, xf)
    # count of knots 1..32 that are <= x == the kernel's compare sum
    idx = jnp.clip(jnp.searchsorted(xs[1:], xf, side="right"), 0, 31)
    y = sl[idx] * xf + ic[idx]
    y = jnp.where(xf < xs[0], cl[0], y)
    y = jnp.where(xf > xs[32], cl[1], y)
    if ane_mode:
        y = y.astype(jnp.float16).astype(jnp.float32)
    return y.astype(x.dtype)


def lut_activation(name: str, *, ane_mode: bool = True):
    xs, sl, ic, cl = _tables(name)

    @jax.custom_jvp
    def f(x):
        return act_lut(x, xs, sl, ic, cl, ane_mode=ane_mode)

    @f.defjvp
    def _jvp(primals, tangents):
        (x,), (dx,) = primals, tangents
        y = f(x)
        # derivative = segment slope (0 outside the domain)
        idx = jnp.clip(jnp.searchsorted(xs, x.astype(jnp.float32)) - 1, 0, 31)
        g = sl[idx]
        g = jnp.where((x < xs[0]) | (x > xs[-1]), 0.0, g)
        return y, (g * dx.astype(jnp.float32)).astype(y.dtype)

    return f
