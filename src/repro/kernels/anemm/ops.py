"""jit'd public wrapper for anemm with training-grade gradients.

Forward runs the Pallas kernel; backward uses standard XLA matmuls (the
universal practice for matmul kernels — the transpose contractions are
themselves plain matmuls XLA already emits optimally). ANE mode is a
serving/emulation path and is non-differentiable by design: the saturation
epilogue has measure-zero gradient support.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.anemm.anemm import anemm as _anemm_kernel


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def matmul(a: jnp.ndarray, b: jnp.ndarray, ane_mode: bool = False):
    return _anemm_kernel(a, b, ane_mode=ane_mode)


def _fwd(a, b, ane_mode):
    return matmul(a, b, ane_mode), (a, b)


def _bwd(ane_mode, res, g):
    a, b = res
    g = g.astype(jnp.float32)
    da = (g @ b.astype(jnp.float32).T).astype(a.dtype)
    db = (a.astype(jnp.float32).T @ g).astype(b.dtype)
    return da, db


matmul.defvjp(_fwd, _bwd)


def linear(a, b, scale=None, bias=None, *, ane_mode: bool = False,
           epilogue: str | None = None):
    """Inference-path linear with the fused epilogue (scale/bias/saturate,
    plus an optional LUT activation evaluated at the output port)."""
    return _anemm_kernel(a, b, scale, bias, ane_mode=ane_mode,
                         epilogue=epilogue)
