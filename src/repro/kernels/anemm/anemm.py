"""anemm: blocked matmul with a wide VMEM accumulator + ANE-mode epilogue.

The kernel is the paper's datapath transcribed to the MXU (§3.1/§3.2):

    inputs round to the narrow dtype on the way in            (HBM -> VMEM)
    products accumulate in a wide fp32 register               (VMEM scratch)
    optional per-channel scale and bias apply                 (epilogue)
    the accumulator OUTPUT PORT saturates at 2^15             (ANE mode)
    the store rounds to the narrow dtype (RTNE)               (VMEM -> HBM)

Grid: (M/bm, N/bn, K/bk) with K innermost ("arbitrary"); the fp32
accumulator lives in VMEM scratch across the K steps and is written out
exactly once — two rounding points bracketing the reduction, like the
engine.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hal
from repro.kernels import compat
from repro.kernels.act_lut.act_lut import lut_eval
from repro.kernels.common import cdiv, interpret_mode, pad_to, pick_block


def _kernel(a_ref, b_ref, scale_ref, bias_ref, lut_refs, o_ref, acc_ref, *,
            nk: int, ane_mode: bool, out_dtype):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k_idx == nk - 1)
    def _epilogue():
        acc = acc_ref[...]
        if scale_ref is not None:
            acc = acc * scale_ref[...].astype(jnp.float32)
        if bias_ref is not None:
            acc = acc + bias_ref[...].astype(jnp.float32)
        if ane_mode:
            # the MAC output-port ceiling: |x| >= 2^15 -> +-inf (paper §3.7)
            acc = jnp.where(acc >= hal.ACCUM_OUT_CEILING, jnp.inf, acc)
            acc = jnp.where(acc <= -hal.ACCUM_OUT_CEILING, -jnp.inf, acc)
        if lut_refs is not None:
            # fused LUT activation (paper §3.5: the activation unit sits on
            # the producing op's output port, no extra dispatch/HBM trip).
            # Round to the out dtype first — the separate-op pipeline stores
            # the matmul and reloads it through act_lut's fp32 widening, so
            # this rounding is what makes fused == kernel-then-LUT, bit for
            # bit.
            acc = acc.astype(out_dtype).astype(jnp.float32)
            acc = lut_eval(acc, *lut_refs, ane_mode=True)
        o_ref[...] = acc.astype(out_dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "ane_mode", "epilogue"))
def anemm(
    a: jnp.ndarray,                 # (M, K)
    b: jnp.ndarray,                 # (K, N)
    scale: jnp.ndarray | None = None,   # (N,) per-output-channel
    bias: jnp.ndarray | None = None,    # (N,)
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    ane_mode: bool = False,
    epilogue: str | None = None,    # LUT activation fused at the output port
) -> jnp.ndarray:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    out_dtype = a.dtype
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    bk = pick_block(k, bk)
    ap = pad_to(pad_to(a, 0, bm), 1, bk)
    bp = pad_to(pad_to(b, 0, bk), 1, bn)
    nm, nn, nk = cdiv(ap.shape[0], bm), cdiv(bp.shape[1], bn), cdiv(ap.shape[1], bk)

    operands = [ap, bp]
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    if scale is not None:
        operands.append(pad_to(scale.reshape(1, -1), 1, bn))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
    if bias is not None:
        operands.append(pad_to(bias.reshape(1, -1), 1, bn))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
    if epilogue is not None:
        from repro.kernels.act_lut.ops import lut_table_operands
        operands.extend(lut_table_operands(epilogue))
        in_specs.extend(
            pl.BlockSpec((1, c), lambda i, j, kk: (0, 0))
            for c in (33, 32, 32, 2))

    def kernel(*refs):
        a_ref, b_ref = refs[0], refs[1]
        idx = 2
        scale_ref = bias_ref = lut_refs = None
        if scale is not None:
            scale_ref = refs[idx]
            idx += 1
        if bias is not None:
            bias_ref = refs[idx]
            idx += 1
        if epilogue is not None:
            lut_refs = refs[idx:idx + 4]
            idx += 4
        o_ref, acc_ref = refs[-2], refs[-1]
        _kernel(a_ref, b_ref, scale_ref, bias_ref, lut_refs, o_ref, acc_ref,
                nk=nk, ane_mode=ane_mode, out_dtype=out_dtype)

    out = pl.pallas_call(
        kernel,
        grid=(nm, nn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nm * bm, nn * bn), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret_mode(),
        **compat.pallas_call_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(*operands)
    return out[:m, :n]
