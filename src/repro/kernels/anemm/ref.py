"""Pure-jnp oracle for anemm."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hal


def anemm_ref(a, b, scale=None, bias=None, *, ane_mode: bool = False):
    acc = jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    if scale is not None:
        acc = acc * scale.astype(jnp.float32)[None, :]
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)[None, :]
    if ane_mode:
        acc = jnp.where(acc >= hal.ACCUM_OUT_CEILING, jnp.inf, acc)
        acc = jnp.where(acc <= -hal.ACCUM_OUT_CEILING, -jnp.inf, acc)
    return acc.astype(a.dtype)
