"""Pure-jnp oracle for anemm."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hal


def anemm_ref(a, b, scale=None, bias=None, *, ane_mode: bool = False,
              epilogue: str | None = None):
    acc = jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    if scale is not None:
        acc = acc * scale.astype(jnp.float32)[None, :]
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)[None, :]
    if ane_mode:
        acc = jnp.where(acc >= hal.ACCUM_OUT_CEILING, jnp.inf, acc)
        acc = jnp.where(acc <= -hal.ACCUM_OUT_CEILING, -jnp.inf, acc)
    out = acc.astype(a.dtype)
    if epilogue is not None:
        # same semantics as the fused kernel: the matmul result rounds to the
        # out dtype, then the LUT evaluates it through the fp32 widening
        from repro.kernels.act_lut.ops import lut_apply_ref
        out = lut_apply_ref(out, epilogue)
    return out
