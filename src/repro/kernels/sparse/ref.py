"""Pure-jnp oracle for sparse_matmul: reconstruct dense, then matmul."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.sparse.sparse_matmul import unpack_dense


def sparse_matmul_ref(a, values, selector):
    w = unpack_dense(values, selector)
    return jax.lax.dot_general(a, w.astype(a.dtype), (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32
                               ).astype(a.dtype)
