"""Public wrapper for the pair-structured sparse linear."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.kernels.sparse.sparse_matmul import pack_pair_sparse, sparse_matmul


@dataclasses.dataclass
class SparseLinear:
    values: jnp.ndarray
    selector: jnp.ndarray
    shape: tuple[int, int]

    @classmethod
    def pack(cls, w: np.ndarray) -> "SparseLinear":
        vals, sel = pack_pair_sparse(w)
        return cls(jnp.asarray(vals), jnp.asarray(sel), tuple(w.shape))

    def __call__(self, a: jnp.ndarray) -> jnp.ndarray:
        return sparse_matmul(a, self.values, self.selector)

    def hbm_bytes(self) -> int:
        return (self.values.size * self.values.dtype.itemsize
                + self.selector.size)

    def dense_bytes(self) -> int:
        return self.shape[0] * self.shape[1] * 2
