"""sparse_matmul: 50% pair-structured sparse weights, streamed compressed.

The paper's structured-sparsity form (§7.2/§7.3): a 1-bit keep mask plus the
packed fp16 nonzeros streams on every ANE generation — 1.55-1.64x faster at
0.43x the bytes on the M1. The TPU-native structure (DESIGN.md §2): exactly
one survivor per adjacent pair along K (like GPU 2:4 but 1:2), stored as

    values    (K/2, N)  fp16/bf16    — the packed nonzeros
    selector  (K/16, N) uint8        — one bit per pair, packed 8/byte

Both stream HBM->VMEM compressed (~0.53x dense bytes); the kernel unpacks
the selector bits with shift/mask (no gather) and reconstructs the dense
(bk, bn) tile at the MXU input — the multiplier-input reconstruction point.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat
from repro.kernels.common import cdiv, interpret_mode, pad_to, pick_block


def pack_pair_sparse(w: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Magnitude-based 1:2 structured pruning + packing.

    Returns (values (K/2, N) float16, selector (K/16, N) uint8)."""
    w = np.asarray(w, dtype=np.float32)
    assert w.ndim == 2 and w.shape[0] % 16 == 0, "K must be divisible by 16"
    k, n = w.shape
    pairs = w.reshape(k // 2, 2, n)
    sel = (np.abs(pairs[:, 1, :]) > np.abs(pairs[:, 0, :])).astype(np.uint8)
    vals = np.where(sel, pairs[:, 1, :], pairs[:, 0, :]).astype(np.float16)
    bits = sel.reshape(-1, 8, n)
    weights_of_bit = (1 << np.arange(8, dtype=np.uint8))[None, :, None]
    packed = (bits * weights_of_bit).sum(axis=1).astype(np.uint8)
    return vals, packed


def unpack_dense(values: jnp.ndarray, selector: jnp.ndarray) -> jnp.ndarray:
    """Reference reconstruction to a dense (K, N) weight."""
    k2, n = values.shape
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (selector[:, None, :] >> shifts[None, :, None]) & 1
    sel = bits.reshape(-1, n)[:k2]
    v32 = values.astype(jnp.float32)
    lo = jnp.where(sel == 0, v32, 0.0)
    hi = jnp.where(sel == 1, v32, 0.0)
    return jnp.stack([lo, hi], axis=1).reshape(k2 * 2, n)


def _kernel(a_ref, v_ref, s_ref, o_ref, acc_ref, *, nk, out_dtype):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    vals = v_ref[...].astype(jnp.float32)        # (bk/2, bn)
    packed = s_ref[...]                          # (bk/16, bn) uint8
    bk2, bn = vals.shape
    # unpack 8 selector bits per byte along K (shift/mask, no gather)
    shifts = jax.lax.broadcasted_iota(jnp.uint8, (1, 8, 1), 1)
    bits = (packed[:, None, :] >> shifts) & 1    # (bk/16, 8, bn)
    sel = bits.reshape(bk2, bn)
    w_lo = jnp.where(sel == 0, vals, 0.0)
    w_hi = jnp.where(sel == 1, vals, 0.0)
    w = jnp.stack([w_lo, w_hi], axis=1).reshape(bk2 * 2, bn)
    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], w.astype(a_ref.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k_idx == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def sparse_matmul(
    a: jnp.ndarray,                 # (M, K)
    values: jnp.ndarray,            # (K/2, N)
    selector: jnp.ndarray,          # (K/16, N) uint8
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
) -> jnp.ndarray:
    m, k = a.shape
    k2, n = values.shape
    assert k == 2 * k2 and selector.shape == (k // 16, n)
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    bk = max(16, pick_block(k, bk))
    ap = pad_to(pad_to(a, 0, bm), 1, bk)
    vp = pad_to(pad_to(values, 0, bk // 2), 1, bn)
    sp = pad_to(pad_to(selector, 0, bk // 16), 1, bn)
    nm, nn, nk = cdiv(ap.shape[0], bm), cdiv(vp.shape[1], bn), cdiv(ap.shape[1], bk)

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, out_dtype=a.dtype),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // 16, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nm * bm, nn * bn), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret_mode(),
        **compat.pallas_call_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(ap, vp, sp)
    return out[:m, :n]
