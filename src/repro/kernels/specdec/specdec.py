"""specdec: fused speculative-decoding verify/accept (paper §9 economics).

The engine's fixed per-dispatch floor dominates decode (§9.3/§9.4), so the
only way to cut per-token cost is more tokens per dispatch. Speculative
decoding buys exactly that: a cheap drafter proposes K tokens, the target
scores all K+1 positions in one dispatch, and this kernel performs the
accept/reject math *on device* so the token chain never round-trips the
host inside a window:

  * **per-position resample** — the target's pick at every drafted position:
    a first-index argmax over the (possibly gumbel-perturbed) score rows.
    With raw logits this is greedy; with per-(rid, pos) gumbel noise added
    by `ops.seeded_scores` it is bit-identical to
    `jax.random.categorical(fold_in(fold_in(root, rid), pos), logits)` —
    the host `TokenSampler`'s draw.
  * **accept-prefix selection** — the longest prefix of draft tokens that
    matches the target's picks position by position. Accepted tokens ARE
    the target's picks, so the emitted stream is always the target
    sampler's stream regardless of what the drafter proposed.
  * **bonus token** — the target's pick at the first mismatch (or at the
    position past the last draft token when everything matched): every
    window emits `accept_len + 1` tokens.

The argmax is gather-free, as the VPU wants it: row max, then a min-reduce
over an iota masked to the argmax positions — first-index tie-breaking,
exactly `jnp.argmax`'s contract (and the ANE's argmax feature byte
0x4f2_argmax_hw gates the capability row).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv, interpret_mode

NEG_INF = float("-inf")


def _kernel(scores_ref, draft_ref, samp_ref, acc_ref, *, t: int, v: int):
    """One lane's window: scores (1, T, Vp) f32, draft (1, max(T-1, 1)) i32
    -> samples (1, T) i32, accept_len (1, 1) i32."""
    s = scores_ref[0].astype(jnp.float32)            # (T, Vp)
    col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(col < v, s, NEG_INF)               # padding never wins
    m = jnp.max(s, axis=-1, keepdims=True)
    # first-index argmax: smallest column index attaining the row max
    idx = jnp.min(jnp.where(s == m, col, v), axis=-1).astype(jnp.int32)  # (T,)
    samp_ref[0, :] = idx
    # accept-prefix: position i accepts iff every draft token up to and
    # including i equals the target's pick there (T is static; unrolled)
    alive = jnp.int32(1)
    acc = jnp.int32(0)
    for i in range(t - 1):
        alive = alive * (draft_ref[0, i] == idx[i]).astype(jnp.int32)
        acc = acc + alive
    acc_ref[0, 0] = acc


def _tree_kernel(scores_ref, draft_ref, samp_ref, acc_ref, br_ref, *,
                 nbr: int, t: int, v: int):
    """One lane's draft tree: scores (1, NBR, T, Vp) f32, draft
    (1, NBR, max(T-1, 1)) i32 -> samples (1, T) i32, accept_len (1, 1) i32,
    branch (1, 1) i32.

    Each branch is an independent chain sharing the window's first position;
    the per-branch math is exactly `_kernel`'s accept-prefix scan, then the
    winning branch is the one with the longest accepted prefix (first-index
    tie-break, so NBR=1 degenerates to the chain kernel bit for bit — ties
    between sibling branches only happen when their accepted prefixes are
    identical token strings anyway, because accepted tokens ARE the target's
    picks)."""
    s = scores_ref[0].astype(jnp.float32)            # (NBR, T, Vp)
    col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
    s = jnp.where(col < v, s, NEG_INF)               # padding never wins
    m = jnp.max(s, axis=-1, keepdims=True)
    # first-index argmax per (branch, position)
    idx = jnp.min(jnp.where(s == m, col, v), axis=-1).astype(jnp.int32)
    alive = jnp.ones((nbr,), jnp.int32)              # idx: (NBR, T)
    acc = jnp.zeros((nbr,), jnp.int32)
    for i in range(t - 1):
        alive = alive * (draft_ref[0, :, i] == idx[:, i]).astype(jnp.int32)
        acc = acc + alive
    best = jnp.max(acc)
    bidx = jax.lax.broadcasted_iota(jnp.int32, (nbr,), 0)
    win = jnp.min(jnp.where(acc == best, bidx, nbr)).astype(jnp.int32)
    acc_ref[0, 0] = best
    br_ref[0, 0] = win
    # the winning branch's picks, gather-free: one-hot select over NBR
    onehot = (bidx[:, None] == win).astype(jnp.int32)           # (NBR, 1)
    samp_ref[0, :] = jnp.sum(idx * onehot, axis=0)


@functools.partial(jax.jit, static_argnames=("vocab",))
def verify_accept_tree_kernel(scores: jnp.ndarray, draft: jnp.ndarray, *,
                              vocab: int | None = None):
    """Fused verify/accept over a *tree* of speculative branches.

    scores: (B, NBR, T, V) fp32 — target scores per (branch, position); the
        NBR branches of a lane share position 0's context and diverge on
        their first proposed token.
    draft:  (B, NBR, T-1) int32 — each branch's proposal chain.
    Returns (samples (B, T) int32, accept_len (B,) int32, branch (B,) int32):
    the winning branch's target picks, its matched-prefix length (the max
    over branches, first index on ties), and which branch won; the window
    emits `samples[:, :accept_len + 1]`.
    """
    b, nbr, t, v = scores.shape
    vocab = v if vocab is None else vocab
    if nbr < 1:
        raise ValueError(f"tree needs >= 1 branch, got {nbr}")
    if draft.shape != (b, nbr, t - 1):
        raise ValueError(f"draft {draft.shape} does not pair with scores "
                         f"{scores.shape}; want ({b}, {nbr}, {t - 1})")
    vp = 128 * cdiv(max(v, 1), 128)
    sp = jnp.pad(scores.astype(jnp.float32),
                 ((0, 0), (0, 0), (0, 0), (0, vp - v)),
                 constant_values=NEG_INF)
    dp = draft.astype(jnp.int32) if t > 1 else \
        jnp.full((b, nbr, 1), -1, jnp.int32)
    samples, accept, branch = pl.pallas_call(
        functools.partial(_tree_kernel, nbr=nbr, t=t, v=min(v, vocab)),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, nbr, t, vp), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, nbr, dp.shape[2]), lambda i: (i, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, t), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ),
        interpret=interpret_mode(),
    )(sp, dp)
    return samples, accept[:, 0], branch[:, 0]


@functools.partial(jax.jit, static_argnames=("vocab",))
def verify_accept_kernel(scores: jnp.ndarray, draft: jnp.ndarray, *,
                         vocab: int | None = None):
    """Fused verify/accept over a speculative window.

    scores: (B, T, V) fp32 — target scores per position (logits, or
        gumbel-perturbed logits for seeded categorical streams).
    draft:  (B, T-1) int32 — the drafter's proposals for positions 1..T-1
        of the window (position 0 has no proposal: its pick seeds the
        window's first emitted token).
    Returns (samples (B, T) int32, accept_len (B,) int32): the target's
    per-position picks and the matched-prefix length; the window emits
    `samples[:, :accept_len + 1]`.
    """
    b, t, v = scores.shape
    vocab = v if vocab is None else vocab
    if draft.shape != (b, t - 1):
        raise ValueError(f"draft {draft.shape} does not pair with scores "
                         f"{scores.shape}; want ({b}, {t - 1})")
    vp = 128 * cdiv(max(v, 1), 128)
    sp = jnp.pad(scores.astype(jnp.float32), ((0, 0), (0, 0), (0, vp - v)),
                 constant_values=NEG_INF)
    # a zero-width draft (bonus-only window) still needs a real operand
    dp = draft.astype(jnp.int32) if t > 1 else \
        jnp.full((b, 1), -1, jnp.int32)
    samples, accept = pl.pallas_call(
        functools.partial(_kernel, t=t, v=min(v, vocab)),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, t, vp), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, dp.shape[1]), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, t), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ),
        interpret=interpret_mode(),
    )(sp, dp)
    return samples, accept[:, 0]
