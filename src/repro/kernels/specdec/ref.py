"""Oracle reference for the specdec verify/accept kernel.

Pure jnp (traceable, so the capability-gated dispatcher can fall back to it
inside a compiled serving program): per-position first-index argmax over the
score rows, then the matched-prefix length against the draft tokens. The
conformance sweep pins the Pallas kernel to this, case by case.
"""

from __future__ import annotations

import jax.numpy as jnp


def verify_accept_tree_ref(scores: jnp.ndarray, draft: jnp.ndarray):
    """scores (B, NBR, T, V) fp32, draft (B, NBR, T-1) int32 ->
    (samples (B, T) i32, accept_len (B,) i32, branch (B,) i32): per-branch
    accept-prefix lengths, then the first branch attaining the max; the
    returned samples are that branch's per-position picks."""
    b, nbr, t, _ = scores.shape
    picks = jnp.argmax(scores.astype(jnp.float32), axis=-1).astype(jnp.int32)
    if t == 1:
        acc = jnp.zeros((b, nbr), jnp.int32)
    else:
        matches = (draft.astype(jnp.int32) == picks[:, :, : t - 1])
        acc = jnp.sum(jnp.cumprod(matches.astype(jnp.int32), axis=2),
                      axis=2).astype(jnp.int32)
    branch = jnp.argmax(acc, axis=1).astype(jnp.int32)  # first index on ties
    samples = jnp.take_along_axis(picks, branch[:, None, None], axis=1)[:, 0]
    return samples, jnp.max(acc, axis=1), branch


def verify_accept_ref(scores: jnp.ndarray, draft: jnp.ndarray):
    """scores (B, T, V) fp32, draft (B, T-1) int32 ->
    (samples (B, T) int32, accept_len (B,) int32)."""
    b, t, _ = scores.shape
    samples = jnp.argmax(scores.astype(jnp.float32), axis=-1).astype(jnp.int32)
    if t == 1:
        return samples, jnp.zeros((b,), jnp.int32)
    matches = (draft.astype(jnp.int32) == samples[:, : t - 1])
    alive = jnp.cumprod(matches.astype(jnp.int32), axis=1)
    return samples, jnp.sum(alive, axis=1).astype(jnp.int32)
