"""Oracle reference for the specdec verify/accept kernel.

Pure jnp (traceable, so the capability-gated dispatcher can fall back to it
inside a compiled serving program): per-position first-index argmax over the
score rows, then the matched-prefix length against the draft tokens. The
conformance sweep pins the Pallas kernel to this, case by case.
"""

from __future__ import annotations

import jax.numpy as jnp


def verify_accept_ref(scores: jnp.ndarray, draft: jnp.ndarray):
    """scores (B, T, V) fp32, draft (B, T-1) int32 ->
    (samples (B, T) int32, accept_len (B,) int32)."""
    b, t, _ = scores.shape
    samples = jnp.argmax(scores.astype(jnp.float32), axis=-1).astype(jnp.int32)
    if t == 1:
        return samples, jnp.zeros((b,), jnp.int32)
    matches = (draft.astype(jnp.int32) == samples[:, : t - 1])
    alive = jnp.cumprod(matches.astype(jnp.int32), axis=1)
    return samples, jnp.sum(alive, axis=1).astype(jnp.int32)
