"""Public specdec surface: seeded scores + routed verify/accept.

`seeded_scores` turns target logits into the score rows the verify/accept
kernel reduces: raw logits for greedy streams, gumbel-perturbed logits for
seeded categorical streams. The perturbation reproduces
`jax.random.categorical` exactly — `categorical(key, row)` is defined as
`argmax(gumbel(key, row.shape, row.dtype) + row)` — with the same
per-(rid, position) `fold_in` key chain the host `TokenSampler` uses, so a
first-index argmax over the perturbed rows is bit-identical to the host
sampler's draw at that (request, position).

`verify_accept` is the dispatcher-aware entry: the Pallas kernel when the
target's capability surface reaches `argmax`, the jnp oracle otherwise —
one more live cell of the op-by-device matrix.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.specdec.ref import verify_accept_ref, verify_accept_tree_ref
from repro.kernels.specdec.specdec import (verify_accept_kernel,
                                           verify_accept_tree_kernel)


def seeded_scores(logits: jnp.ndarray, root, rids: jnp.ndarray,
                  positions: jnp.ndarray, mode: str) -> jnp.ndarray:
    """logits (B, T, V) -> score rows for `verify_accept`.

    greedy: the raw fp32 logits (first-index argmax == host greedy).
    categorical: logits + gumbel(fold_in(fold_in(root, rid), position)) per
    row, so argmax(scores[b, t]) == jax.random.categorical(key, logits[b, t])
    bit for bit — the host `TokenSampler`'s math, moved on device.
    """
    lg = logits.astype(jnp.float32)
    if mode == "greedy":
        return lg
    if mode != "categorical":
        raise ValueError(f"unknown sampling mode {mode!r}")

    def row(rid, p, r):
        key = jax.random.fold_in(jax.random.fold_in(root, rid), p)
        return r + jax.random.gumbel(key, r.shape, r.dtype)

    return jax.vmap(jax.vmap(row, in_axes=(None, 0, 0)))(rids, positions, lg)


def verify_accept(scores: jnp.ndarray, draft: jnp.ndarray, *,
                  dispatcher=None):
    """Routed verify/accept: (samples (B, T) i32, accept_len (B,) i32).

    With a dispatcher the call resolves through the `specdec` registry row
    (capability-gated on `argmax`, oracle fallback recorded in the route
    census); without one it runs the Pallas kernel directly.
    """
    if dispatcher is None:
        return verify_accept_kernel(scores, draft)
    from repro.models.dispatched import route_and_run

    return route_and_run(
        dispatcher, "specdec", scores.dtype,
        lambda: verify_accept_kernel(scores, draft),
        lambda: verify_accept_ref(scores, draft))


def verify_accept_tree(scores: jnp.ndarray, draft: jnp.ndarray, *,
                       dispatcher=None):
    """Routed tree verify/accept over sibling draft branches per lane:
    (samples (B, T) i32, accept_len (B,) i32, branch (B,) i32).

    Same accept-prefix + bonus-resample math as `verify_accept`, reduced
    over the NBR branch axis on device (max accept, first-index tie-break);
    a single-branch tree is bit-for-bit the chain kernel. Resolves through
    the `specdec_tree` registry row when a dispatcher is given.
    """
    if dispatcher is None:
        return verify_accept_tree_kernel(scores, draft)
    from repro.models.dispatched import route_and_run

    return route_and_run(
        dispatcher, "specdec_tree", scores.dtype,
        lambda: verify_accept_tree_kernel(scores, draft),
        lambda: verify_accept_tree_ref(scores, draft))
