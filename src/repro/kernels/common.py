"""Shared Pallas kernel utilities.

TPU is the target: every kernel is written as `pl.pallas_call` with explicit
BlockSpec VMEM tiling, MXU-aligned block shapes (multiples of 128 on matmul
dims), and VMEM scratch accumulators. On this CPU container the kernels
execute under `interpret=True` (the kernel body runs in Python), which is
how the allclose sweeps in tests/ validate them against the pure-jnp oracles
in each kernel's ref.py.

VMEM budgeting follows the paper's working-set rule (§9.2): block shapes are
chosen so the live tiles fit the per-core budget in `hal.TPU_V5E.onchip_bytes`
— a kernel whose live tiles exceed on-chip memory stalls on streaming, on
the ANE and on the TPU alike.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.compat import interpret_mode  # noqa: F401 — re-exported;
# kernels historically import interpret_mode from here, and the probe now
# lives with the rest of the version-adaptive surface in compat.py.


def pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pick_block(dim: int, preferred: int, align: int = 128) -> int:
    """Largest MXU-aligned block <= preferred that doesn't exceed dim
    (padded). Small dims fall back to the padded dim itself."""
    if dim >= preferred:
        return preferred
    if dim >= align:
        return (dim // align) * align
    # tiny dims: round up to a sublane-friendly size
    for candidate in (8, 16, 32, 64, 128):
        if dim <= candidate:
            return candidate
    return align


def vmem_bytes(*tiles: tuple[tuple[int, ...], int]) -> int:
    """Sum of (shape, dtype_bytes) tile footprints — checked against the
    VMEM budget in kernel wrappers."""
    total = 0
    for shape, nbytes in tiles:
        n = 1
        for s in shape:
            n *= s
        total += n * nbytes
    return total


def select_from_table(idx: jnp.ndarray, values) -> jnp.ndarray:
    """Gather-free table lookup: a log2(len) select tree over scalar table
    entries. TPU Pallas has no general gather from VMEM; for small tables
    (16-entry palettes, 32 LUT segments) a select tree is the native form —
    each level is one vectorized `where` on the index bits.

    idx: integer tile with values in [0, len(values)); values: list of
    scalars (or 0-d arrays). Returns a float32 tile.
    """
    n = len(values)
    assert n & (n - 1) == 0, "table length must be a power of two"
    vals = [jnp.asarray(v, jnp.float32) for v in values]
    level = [jnp.broadcast_to(v, idx.shape) for v in vals]
    bit = 0
    while len(level) > 1:
        b = (idx >> bit) & 1
        nxt = []
        for i in range(0, len(level), 2):
            nxt.append(jnp.where(b == 1, level[i + 1], level[i]))
        level = nxt
        bit += 1
    return level[0]
